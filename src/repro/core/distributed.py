"""Distributed (sharded) NaviX search -- the paper's technique at scale.

Production layout (DESIGN.md Section 4): the vector set V is split into
S shards over the mesh's "model" axis; each shard builds its OWN HNSW
subgraph over its slice (shard-and-merge ANN). Searches are served by the
**batched-frontier engine** (``repro.core.search_batch``) running inside
``shard_map`` on every shard at once: ``Q`` is a ``[B, d]`` batch and the
semimask is either one shared ``[S, W_local]`` bitset (the broadcast fast
path) or a per-lane ``[S, B, W_local]`` stack -- each lane of each shard
searches its own selection subquery's S, with lane-local selectivity
estimates taken against that shard's own slice of S. Mixed-plan request
batches therefore fuse on a sharded index exactly like they do on a
single-device one.

Per-shard ``[S, B, k]`` candidate lists are merged into the global top-k
in one device op: a single lexicographic ``lax.sort`` over the flattened
shard axis keyed on (distance, global id), quorum-masked so dead shards
contribute ``+inf`` rows. Tie-breaking toward the smaller global id makes
the merge deterministic and invariant to shard order (property-tested in
``tests/test_distributed_batch.py``).

Straggler mitigation = quorum merge: searches carry an ``alive`` shard
mask; dead/slow shards contribute empty results and the merge proceeds
when >= quorum shards responded -- recall degrades gracefully instead of
latency collapsing.

The ``*_program`` surface at the bottom mirrors the resumable stepping
API of ``search_batch`` (park / refill / step / finalize) with every
state leaf carrying a leading shard dim, so the serving tier's
continuous-batching scheduler runs unchanged over a sharded index --
refill masks simply gain the shard dimension.

Padded rows: :meth:`ShardedNavix.build` pads the vector set to a
multiple of S with copies of the last row. Padded ids are excluded from
every packed semimask AND structurally guarded in the merge path (a
returned local id whose global id falls past ``n_total`` is dropped), so
a caller-built all-ones local bitset -- or the ONEHOP_A branch, which
ignores the semimask -- can never surface a padded id.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import bitset
from repro.core import search_batch as sb
from repro.core.build import build
from repro.core.distances import normalize
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic
from repro.core.navix import NavixConfig
from repro.core.search import SearchParams, SearchResult, SearchStats

# jax >= 0.6 exposes top-level jax.shard_map (check_vma=); older releases
# ship it under jax.experimental.shard_map with the check_rep= spelling
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_REPL_KW = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_REPL_KW = "check_rep"


def _stack_graphs(graphs: list[HnswGraph]) -> HnswGraph:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *graphs)


def merge_shard_topk(d: jax.Array, ids: jax.Array, k: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard candidates ``([S, B, L], [S, B, L])`` into the
    global top-k ``([B, k], [B, k])`` in one device op.

    A single lexicographic ``lax.sort`` over the flattened shard axis,
    keyed on (distance, global id): equal distances break toward the
    smaller id, so the merge is deterministic and invariant to shard
    order. Padded/dead slots carry ``+inf`` and sort last; any result
    slot left at ``+inf`` comes back with id ``-1``. Requires
    ``k <= S * L``.
    """
    s, b, l = d.shape
    if k > s * l:
        raise ValueError(f"k={k} > S*L={s * l} merge candidates")
    d2 = jnp.swapaxes(d, 0, 1).reshape(b, s * l)
    i2 = jnp.swapaxes(ids, 0, 1).reshape(b, s * l)
    d_sorted, i_sorted = lax.sort((d2, i2), dimension=1, num_keys=2)
    out_d = d_sorted[:, :k]
    return out_d, jnp.where(jnp.isfinite(out_d), i_sorted[:, :k], -1)


def per_shard_reference(sn: "ShardedNavix", Q, masks, params: SearchParams,
                        alive: Optional[np.ndarray] = None):
    """Host-side oracle for the sharded path (tests + bench drift gate).

    Runs the UNSHARDED batched engine (``search_batch.search_many``)
    independently on every shard over shard-restricted masks, applies the
    same structural padded-row guard, and merges with numpy under the
    same (distance, global id) lexicographic rule. The distributed
    equivalence suite asserts the device path is lane-for-lane identical
    to this; ``bench_serving --shards`` gates zero answer drift against
    it. Returns ``(dists[B, k], ids[B, k], stats)`` with stats summed
    over the alive shards.
    """
    s, nl, n = sn.n_shards, sn.n_local, sn.n_total
    alive = np.ones(s, bool) if alive is None else np.asarray(alive, bool)
    masks = np.asarray(masks, bool)
    Qp = jnp.atleast_2d(sn._prep_query(Q))
    padded = np.zeros((masks.shape[0], s * nl), bool)
    padded[:, :n] = masks
    ds, gs, stats = [], [], []
    for si in range(s):
        graph_s = jax.tree.map(lambda x: x[si], sn.graphs)
        sel_s = bitset.pack(jnp.asarray(padded[:, si * nl:(si + 1) * nl]))
        res = sb.search_many(graph_s, Qp, sel_s, params)
        d, ids = np.asarray(res.dists), np.asarray(res.ids)
        ok = (ids >= 0) & (ids + si * nl < n) & alive[si]
        ds.append(np.where(ok, d, np.inf))
        gs.append(np.where(ok, ids + si * nl, -1))
        stats.append(jax.tree.map(np.asarray, res.stats))
    D, I = np.concatenate(ds, 1), np.concatenate(gs, 1)
    k = params.k
    out_d = np.empty((D.shape[0], k), D.dtype)
    out_i = np.empty((D.shape[0], k), I.dtype)
    for b in range(D.shape[0]):
        order = np.lexsort((I[b], D[b]))[:k]
        out_d[b] = D[b][order]
        out_i[b] = np.where(np.isfinite(out_d[b]), I[b][order], -1)
    stat_sum = jax.tree.map(
        lambda *xs: sum(x * int(a) for x, a in zip(xs, alive)), *stats)
    return out_d, out_i, stat_sum


def _masked_stats_sum(stats: SearchStats, alive: jax.Array) -> SearchStats:
    """Sum per-shard stats ([S, B, ...] leaves) over the alive shards."""
    am = alive.astype(jnp.int32)
    return jax.tree.map(
        lambda x: (x * am.reshape((-1,) + (1,) * (x.ndim - 1))).sum(axis=0),
        stats)


@dataclasses.dataclass
class ShardedNavix:
    mesh: Mesh
    graphs: HnswGraph          # every leaf has leading [S] shard dim
    n_local: int               # vectors per shard (padded)
    n_total: int
    config: NavixConfig
    model_axis: str = "model"
    data_axis: str = "data"
    # set when the index is registered in a NavixDB catalog; routes search
    # through the shared compiled-program cache (repro.api.plan_compile)
    program_cache: Optional[object] = None
    # memoized jitted shard_map programs:
    # (kind, params, per_lane, donate) -> fn
    _programs: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def lane_shards(self) -> int:
        """Size of the DATA axis: how many ways the lane (batch) dim of
        every stepping-surface buffer is split. With ``lane_shards > 1``
        each device along the data axis steps only ``B / lane_shards``
        lanes (the state specs already partition the lane dim with
        ``P(model, data, ...)``), so batch throughput scales across the
        data axis instead of every device stepping the full batch. Batch
        sizes must be a multiple of this."""
        return int(self.mesh.shape[self.data_axis])

    def _check_lanes(self, bsz: int) -> None:
        if bsz % self.lane_shards:
            raise ValueError(
                f"batch size {bsz} is not divisible by the data-axis "
                f"size {self.lane_shards}; pad the batch (the program "
                f"cache's bucket already rounds to a multiple)")

    @property
    def dim(self) -> int:
        return int(self.graphs.vectors.shape[-1])

    @property
    def n_words_local(self) -> int:
        return bitset.n_words(self.n_local)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, config: NavixConfig, mesh: Mesh,
              model_axis: str = "model", data_axis: str = "data"
              ) -> "ShardedNavix":
        n, d = vectors.shape
        s = int(mesh.shape[model_axis])
        n_local = -(-n // s)
        pad = s * n_local - n
        if pad:
            # pad with copies of the last row; padded ids are excluded
            # from every packed semimask AND structurally guarded in the
            # merge path, so they can never be returned
            vectors = np.concatenate([vectors, np.repeat(vectors[-1:], pad, 0)])
        graphs = []
        for i in range(s):
            sl = vectors[i * n_local:(i + 1) * n_local]
            g, _ = build(jnp.asarray(sl), config.build_params())
            graphs.append(g)
        stacked = _stack_graphs(graphs)
        spec = jax.tree.map(lambda x: NamedSharding(
            mesh, P(model_axis, *([None] * (x.ndim - 1)))), stacked)
        stacked = jax.tree.map(jax.device_put, stacked, spec)
        return cls(mesh=mesh, graphs=stacked, n_local=n_local, n_total=n,
                   config=config, model_axis=model_axis, data_axis=data_axis)

    # -- semimasks ------------------------------------------------------
    def shard_semimask(self, mask) -> jax.Array:
        """Pack a semimask for the shard layout (padded rows excluded).

        ``bool[n_total]`` -> shared ``u32[S, W_local]``;
        ``bool[B, n_total]`` (or a list of B masks, ``None`` entries =
        unfiltered) -> per-lane ``u32[S, B, W_local]``. Pre-packed
        ``u32[S, W]`` / ``u32[S, B, W]`` pass through after a shape
        check.
        """
        s, nl = self.n_shards, self.n_local
        if isinstance(mask, (list, tuple)):
            mask = np.stack([np.ones(self.n_total, bool) if m is None
                             else np.asarray(m, bool) for m in mask])
        mask = np.asarray(mask)
        if mask.dtype == np.uint32:
            want = (s, self.n_words_local)
            if mask.ndim not in (2, 3) or (mask.shape[0], mask.shape[-1]) \
                    != want:
                raise ValueError(
                    f"pre-packed sharded semimask has shape {mask.shape}; "
                    f"this index needs [S={s}, ..., W={want[1]}]")
            packed = jnp.asarray(mask)
        else:
            packed = jnp.asarray(self.shard_semimask_np(mask))
        return jax.device_put(packed, NamedSharding(
            self.mesh, P(self.model_axis,
                         *([None] * (packed.ndim - 1)))))

    def shard_semimask_np(self, mask) -> np.ndarray:
        """Host-side :meth:`shard_semimask` body for bool masks:
        ``bool[..., n_total]`` -> ``u32[S, ..., W_local]`` as a numpy
        array (no device transfer). The serving tier packs one row per
        distinct plan between device chunks; packing on the host keeps
        that work off the dispatch path."""
        s, nl = self.n_shards, self.n_local
        mask = np.asarray(mask, bool)
        if mask.shape[-1] != self.n_total:
            raise ValueError(
                f"semimask covers {mask.shape[-1]} nodes but this index "
                f"has {self.n_total}")
        m = np.zeros(mask.shape[:-1] + (s * nl,), bool)
        m[..., :self.n_total] = mask
        m = np.moveaxis(m.reshape(mask.shape[:-1] + (s, nl)), -2, 0)
        return bitset.pack_np(m)

    def full_semimask(self) -> jax.Array:
        """Shared all-ones semimask ``u32[S, W_local]`` over the real
        (non-padded) rows."""
        return self.shard_semimask(np.ones(self.n_total, bool))

    def sigma(self, sel_bits: jax.Array):
        """Selectivity |S| / |V|: float for a shared [S, W] mask, f32[B]
        per lane for a per-lane [S, B, W] stack."""
        tot = bitset.count_batch(sel_bits).sum(axis=0)
        if sel_bits.ndim == 3:
            return tot.astype(jnp.float32) / self.n_total
        return float(tot) / self.n_total

    # -- params / query prep (mirrors NavixIndex) -----------------------
    def _params(self, k, efs, heuristic, max_iters=0) -> SearchParams:
        h = (Heuristic.from_name(heuristic) if isinstance(heuristic, str)
             else Heuristic(heuristic))
        return SearchParams(k=k, efs=max(efs, k), heuristic=int(h),
                            metric=self.config.metric, max_iters=max_iters)

    def _prep_query(self, q) -> jax.Array:
        q = jnp.asarray(q, dtype=jnp.float32)
        if self.config.metric == "cos":
            q = normalize(q)
        return q

    # -- shard_map program construction ---------------------------------
    # Every program takes the graph pytree as an argument (no captured
    # array constants) and is memoized on self, so repeated drains /
    # searches of the same plan shape never rebuild or retrace.

    def _graph_specs(self):
        specs = jax.tree.map(
            lambda x: P(self.model_axis, *([None] * (x.ndim - 1))),
            self.graphs)
        return tuple(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))

    def _state_specs(self, bsz: int, params: SearchParams):
        """shard_map specs for a shard-stacked _BatchState pytree."""
        template = jax.eval_shape(
            lambda: sb.parked_state(self.n_local, bsz, params))
        return jax.tree.map(
            lambda x: P(self.model_axis, self.data_axis,
                        *([None] * (x.ndim - 1))), template)

    def _sel_spec(self, per_lane: bool):
        return (P(self.model_axis, self.data_axis, None) if per_lane
                else P(self.model_axis, None))

    def _guard(self, local_ids: jax.Array, d: jax.Array, my_alive):
        """Local ids -> global ids with the padded-row + liveness guard.

        A padded slot duplicates the last real row; its global id falls
        at/after ``n_total`` and is dropped here even if a caller-built
        semimask (or the ONEHOP_A branch, which ignores the semimask)
        let it into the beam.
        """
        sidx = lax.axis_index(self.model_axis)
        gids = local_ids + sidx * self.n_local
        ok = (local_ids >= 0) & (gids < self.n_total) & my_alive
        return (jnp.where(ok, d, jnp.inf), jnp.where(ok, gids, -1))

    def _program(self, kind: str, params: SearchParams,
                 per_lane: bool = True, donate: bool = False):
        key = (kind, params, bool(per_lane), bool(donate))
        fn = self._programs.get(key)
        if fn is None:
            if kind in ("steps", "refill"):
                fn = getattr(self, f"_build_{kind}")(params, per_lane,
                                                     donate)
            else:
                fn = getattr(self, f"_build_{kind}")(params, per_lane)
            self._programs[key] = fn
        return fn

    def _build_search(self, params: SearchParams, per_lane: bool):
        """One-shot batched search over every shard + the global merge."""
        mesh, model, data = self.mesh, self.model_axis, self.data_axis
        structure = jax.tree.structure(self.graphs)
        graph_specs = self._graph_specs()
        k = params.k

        def local(graph_leaves, q, sel, alive):
            graph = jax.tree.map(
                lambda x: x[0], jax.tree.unflatten(structure, graph_leaves))
            # lane-local sigma estimates against this shard's own slice
            # of S (sigma_g=None -> per-lane |S_local| / n_local)
            res = sb.search_lanes(graph, q, sel[0], params, sigma_g=None)
            my_alive = alive[lax.axis_index(model)]
            d, gids = self._guard(res.ids, res.dists, my_alive)
            return (d[None], gids[None],
                    jax.tree.map(lambda x: x[None], res.stats))

        stats_specs = SearchStats(
            iters=P(model, data), t_dc=P(model, data), s_dc=P(model, data),
            upper_dc=P(model, data), picks=P(model, data, None))

        @jax.jit
        def run(graphs, Q, sel_bits, alive):
            d, gids, stats = _shard_map(
                local, mesh=mesh,
                in_specs=(graph_specs, P(data, None),
                          self._sel_spec(per_lane), P()),
                out_specs=(P(model, data, None), P(model, data, None),
                           stats_specs),
                **{_CHECK_REPL_KW: False},
            )(tuple(jax.tree.leaves(graphs)), Q, sel_bits, alive)
            out_d, out_i = merge_shard_topk(d, gids, k)
            return SearchResult(dists=out_d, ids=out_i,
                                stats=_masked_stats_sum(stats, alive))

        return run

    def _build_refill(self, params: SearchParams, per_lane: bool,
                      donate: bool = False):
        mesh, model, data = self.mesh, self.model_axis, self.data_axis
        structure = jax.tree.structure(self.graphs)
        graph_specs = self._graph_specs()

        def local(graph_leaves, q, sel, st, udc, refill):
            graph = jax.tree.map(
                lambda x: x[0], jax.tree.unflatten(structure, graph_leaves))
            st = jax.tree.map(lambda x: x[0], st)
            st2, udc2 = sb.refill_lanes(graph, q, sel[0], st, udc[0],
                                        refill, params)
            return jax.tree.map(lambda x: x[None], st2), udc2[None]

        # donate=True consumes st/udc in place (the serving tier's
        # overlapped path); the sharding of a donated buffer matches its
        # output, so donation composes with the (model, data) state specs
        @functools.partial(jax.jit,
                           donate_argnums=(3, 4) if donate else ())
        def run(graphs, Q, sel_bits, st, udc, refill):
            state_specs = self._state_specs(Q.shape[0], params)
            return _shard_map(
                local, mesh=mesh,
                in_specs=(graph_specs, P(data, None),
                          self._sel_spec(per_lane), state_specs,
                          P(model, data), P(data)),
                out_specs=(state_specs, P(model, data)),
                **{_CHECK_REPL_KW: False},
            )(tuple(jax.tree.leaves(graphs)), Q, sel_bits, st, udc, refill)

        return run

    def _build_steps(self, params: SearchParams, per_lane: bool,
                     donate: bool = False):
        mesh, model, data = self.mesh, self.model_axis, self.data_axis
        structure = jax.tree.structure(self.graphs)
        graph_specs = self._graph_specs()

        @functools.partial(jax.jit, static_argnames=("n_steps",),
                           donate_argnums=(3,) if donate else ())
        def run(graphs, Q, sel_bits, st, n_steps, efs_lanes=None):
            def local(graph_leaves, q, sel, stl, *efsl):
                graph = jax.tree.map(
                    lambda x: x[0],
                    jax.tree.unflatten(structure, graph_leaves))
                stl = jax.tree.map(lambda x: x[0], stl)
                # sigma_g=None: each shard's lanes estimate against their
                # own slice of S, exactly like the one-shot path
                st2, live = sb.step_lanes(
                    graph, q, sel[0], stl, params, n_steps, sigma_g=None,
                    efs_lanes=efsl[0] if efsl else None)
                return jax.tree.map(lambda x: x[None], st2), live[None]

            state_specs = self._state_specs(Q.shape[0], params)
            in_specs = (graph_specs, P(data, None),
                        self._sel_spec(per_lane), state_specs)
            args = (tuple(jax.tree.leaves(graphs)), Q, sel_bits, st)
            if efs_lanes is not None:
                # ragged per-lane efs rides the lane split: [B] over data
                in_specs += (P(data),)
                args += (efs_lanes,)
            st2, live = _shard_map(
                local, mesh=mesh,
                in_specs=in_specs,
                out_specs=(state_specs, P(model, data)),
                **{_CHECK_REPL_KW: False},
            )(*args)
            # a lane is live while ANY shard's beam still advances
            return st2, jnp.any(live, axis=0)

        return run

    def _build_finalize(self, params: SearchParams, per_lane: bool = True):
        mesh, model, data = self.mesh, self.model_axis, self.data_axis
        efs = params.efs

        def local(st, udc, alive):
            st = jax.tree.map(lambda x: x[0], st)
            res = sb.finalize_lanes(st, udc[0], params)
            my_alive = alive[lax.axis_index(model)]
            d, gids = self._guard(res.ids, res.dists, my_alive)
            return (d[None], gids[None],
                    jax.tree.map(lambda x: x[None], res.stats))

        stats_specs = SearchStats(
            iters=P(model, data), t_dc=P(model, data), s_dc=P(model, data),
            upper_dc=P(model, data), picks=P(model, data, None))

        @jax.jit
        def run(st, udc, alive):
            state_specs = self._state_specs(udc.shape[1], params)
            d, gids, stats = _shard_map(
                local, mesh=mesh,
                in_specs=(state_specs, P(model, data), P()),
                out_specs=(P(model, data, None), P(model, data, None),
                           stats_specs),
                **{_CHECK_REPL_KW: False},
            )(st, udc, alive)
            out_d, out_i = merge_shard_topk(d, gids, efs)
            return SearchResult(dists=out_d, ids=out_i,
                                stats=_masked_stats_sum(stats, alive))

        return run

    def _build_finalize_beams(self, params: SearchParams,
                              per_lane: bool = True):
        """ids/dists-only finalize for the serving hot loop: the same
        per-shard :func:`~repro.core.search_batch.finalize_lanes` +
        liveness guard + :func:`merge_shard_topk` as
        :meth:`_build_finalize`, spelled as a plain jitted vmap over the
        shard dim. Skipping the shard_map round-trip and the stats
        reduction (lane drivers never read stats) is a measurable
        per-call win, and the beam math is identical op-for-op, so the
        merged ids/dists stay bitwise equal to the full finalize."""
        del per_lane                      # lane semimasks don't reach finalize
        efs = params.efs

        @jax.jit
        def run(st, udc, alive):
            res = jax.vmap(
                lambda s, u: sb.finalize_lanes(s, u, params))(st, udc)
            sidx = jnp.arange(res.ids.shape[0])[:, None, None]
            gids = res.ids + sidx * self.n_local
            ok = ((res.ids >= 0) & (gids < self.n_total)
                  & alive[:, None, None])
            return merge_shard_topk(jnp.where(ok, res.dists, jnp.inf),
                                    jnp.where(ok, gids, -1), efs)

        return run

    # -- resumable stepping surface (the serving tier's device side) ----
    def parked_state(self, bsz: int, params: SearchParams):
        """All-parked shard-stacked batch state (+ its [S, B] upper_dc)."""
        self._check_lanes(bsz)
        st = sb.parked_state(self.n_local, bsz, params)
        st = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_shards,) + x.shape),
            st)
        udc = jnp.zeros((self.n_shards, bsz), jnp.int32)
        # place on the mesh up front: state fed to the *_program surface
        # with single-device sharding costs a reshard (and a second
        # executable) on the first call
        st = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            st, self._state_specs(bsz, params))
        return st, jax.device_put(
            udc, NamedSharding(self.mesh, P(self.model_axis,
                                            self.data_axis)))

    def refill_program(self, params: SearchParams, per_lane: bool = True,
                       donate: bool = False):
        """(graphs, Q, sel_bits, st, udc, refill[B]) -> (st, udc); the
        sharded ``engine_refill`` -- the refill mask simply applies to
        every shard's copy of the lane. With ``donate=True`` the ``st``
        and ``udc`` buffers are donated (callers must drop their own
        references after the call)."""
        return self._program("refill", params, per_lane, donate)

    def steps_program(self, params: SearchParams, per_lane: bool = True,
                      donate: bool = False):
        """(graphs, Q, sel_bits, st, n_steps, efs_lanes=None) ->
        (st, live[B]); live is the OR over shards of each lane's
        convergence predicate. ``efs_lanes`` (optional ``int32[B]``)
        masks each lane's beam tail beyond its own efs. With
        ``donate=True`` the ``st`` buffers are donated so the device can
        write in place while the host keeps working."""
        return self._program("steps", params, per_lane, donate)

    def finalize_program(self, params: SearchParams):
        """(st, udc, alive[S]) -> SearchResult with merged global ids
        ([B, efs]); dead shards contribute +inf rows to the merge."""
        return self._program("finalize", params, True)

    def finalize_beams_program(self, params: SearchParams):
        """(st, udc, alive[S]) -> (dists[B, efs], ids[B, efs]): the
        serving-tier finalize. Bitwise-identical merged beams to
        :meth:`finalize_program`, minus the stats reduction and the
        shard_map round-trip (ids/dists are all the lane drivers
        consume)."""
        return self._program("finalize_beams", params, True)

    def evict_program(self, params: SearchParams, donate: bool = False):
        """(st, udc, evict[B]) -> (st, udc) with the flagged lanes parked
        on EVERY shard (empty converged beams, zeroed upper_dc) -- the
        sharded ``engine_evict``. The eviction merge is elementwise over
        lanes, so the shape-generic :func:`search_batch.engine_evict`
        serves the shard-stacked ``[S, B, ...]`` state directly (jit
        propagates the model-axis sharding; no shard_map round-trip).
        ``params`` is unused -- kept so the surface mirrors the other
        ``*_program`` constructors."""
        del params
        return sb.engine_evict_overlap if donate else sb.engine_evict

    # -- one-shot search ------------------------------------------------
    def search_many(self, Q, semimask=None, k: int = 10, efs: int = 0,
                    heuristic: str = "adaptive_local",
                    alive: Optional[np.ndarray] = None, quorum: int = 0
                    ) -> SearchResult:
        """Batched filtered search over every shard + one global merge.

        ``semimask``: ``None`` (unfiltered), ``bool[n_total]`` (shared),
        ``bool[B, n_total]`` / list of B masks (per-lane, the mixed-plan
        path), or pre-packed ``u32[S, W]`` / ``u32[S, B, W]``. Returns a
        :class:`SearchResult` with GLOBAL ids ([B, k]) and per-lane stats
        summed over the alive shards. Raises if fewer than ``quorum``
        shards are alive.
        """
        efs = efs or 2 * k
        params = self._params(k, efs, heuristic)
        sel = (self.full_semimask() if semimask is None
               else self.shard_semimask(semimask))
        alive = (np.ones(self.n_shards, bool) if alive is None
                 else np.asarray(alive, bool))
        if alive.shape != (self.n_shards,):
            # an out-of-bounds gather inside jit would silently clamp,
            # handing some shards another shard's liveness
            raise ValueError(f"alive mask has shape {alive.shape}; this "
                             f"index has {self.n_shards} shards")
        if quorum and alive.sum() < quorum:
            raise RuntimeError(
                f"quorum not met: {int(alive.sum())}/{self.n_shards} alive, "
                f"need {quorum}")
        Qp = jnp.atleast_2d(self._prep_query(Q))
        alive_j = jnp.asarray(alive)
        if self.program_cache is not None:
            # the cache pads the lane axis to a bucket that is already
            # rounded up to a lane_shards multiple, so raw B is free here
            return self.program_cache.search_sharded(self, Qp, sel, alive_j,
                                                     params)
        self._check_lanes(Qp.shape[0])
        fn = self._program("search", params, per_lane=sel.ndim == 3)
        return fn(self.graphs, Qp, sel, alive_j)

    # -- compatibility wrappers (pre-batched-engine surface) ------------
    def search_fn(self, k: int, efs: int, heuristic: str = "adaptive_local",
                  per_lane: bool = False):
        """Returns a (Q, sel_bits, alive) -> (dists, ids) function.

        Q: f32[B, d] (B divisible by the data axis); sel_bits:
        u32[S, W] (with ``per_lane=True``, u32[S, B, W] -- a shared
        [S, W] mask is lane-broadcast first); alive: bool[S]. Output ids
        are GLOBAL vector ids; kept as the thin compatibility form of
        :meth:`search_many`'s program.
        """
        params = self._params(k, efs, heuristic)
        fn = self._program("search", params, per_lane=per_lane)

        def run(Q, sel_bits, alive):
            if per_lane:
                sel_bits = bitset.broadcast_shard_lanes(sel_bits,
                                                        Q.shape[0])
            res = fn(self.graphs, Q, sel_bits, alive)
            return res.dists, res.ids

        return run

    def search(self, Q, semimask: np.ndarray, k: int = 100, efs: int = 0,
               heuristic: str = "adaptive_local",
               alive: Optional[np.ndarray] = None, quorum: int = 0):
        """Convenience wrapper returning ``(dists, ids)``; raises if fewer
        than ``quorum`` shards are alive (the serving tier's
        retry/deadline policy decides quorum)."""
        res = self.search_many(Q, semimask=semimask, k=k, efs=efs,
                               heuristic=heuristic, alive=alive,
                               quorum=quorum)
        return res.dists, res.ids
