"""Distance functions.

Conventions (all "distances" are *smaller-is-closer*):
  l2   -- squared Euclidean distance (monotone in L2)
  cos  -- 1 - cosine similarity; vectors are pre-normalized at ingest, so
          this is ``1 - dot``
  dot  -- negative inner product (max-inner-product search)

The pure-jnp forms below are the reference implementations; the Pallas
kernels in ``repro.kernels`` provide the TPU hot paths (tiled distance
matrix, fused gather+distance, int8 quantized distance) and are tested
against these.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l2", "cos", "dot"]

VALID_METRICS = ("l2", "cos", "dot")


def validate_metric(metric: str) -> None:
    if metric not in VALID_METRICS:
        raise ValueError(f"unknown metric {metric!r}; valid: {VALID_METRICS}")


def normalize(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    return x / jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)


def point_dist(q: jax.Array, x: jax.Array, metric: Metric) -> jax.Array:
    """dist(q[..., d], x[..., d]) -> [...] (q broadcasts against x).

    cos/dot use an explicit elementwise multiply + last-axis sum (not a
    matvec) so the single-query and batched search engines -- which call
    this with differently-ranked operands -- produce bitwise-identical
    distances for the same (q, x) rows.
    """
    if metric == "l2":
        diff = x - q
        return jnp.sum(diff * diff, axis=-1)
    if metric == "cos":
        return 1.0 - jnp.sum(x * q, axis=-1)
    if metric == "dot":
        return -jnp.sum(x * q, axis=-1)
    raise ValueError(metric)


def gather_rows(vectors, ids: jax.Array) -> jax.Array:
    """f32 row gather with store dispatch: ``vectors[ids]`` for a plain
    f32 array, per-row dequantize for an int8-resident store.

    ``vectors`` is either ``f32[n, d]`` or a
    :class:`repro.core.quantize.QuantizedStore` (duck-typed on ``codes``
    to avoid an import cycle). For the quantized store the gathered rows
    are ``codes[ids] * scale[ids]`` -- elementwise identical to gathering
    from ``dequantize(store)``, since a gather of an elementwise product
    equals the product of the gathers. Callers are expected to have
    clamped ``ids`` to valid rows already (the ``ids < 0 -> +inf``
    masking stays with the distance wrappers).
    """
    codes = getattr(vectors, "codes", None)
    if codes is None:
        return vectors[ids]
    return codes[ids].astype(jnp.float32) * vectors.scale[ids][..., None]


def gathered_dist(q: jax.Array, vectors: jax.Array, ids: jax.Array,
                  metric: Metric) -> jax.Array:
    """dist(q, vectors[ids]) with ids<0 padding -> +inf."""
    safe = jnp.maximum(ids, 0)
    d = point_dist(q, gather_rows(vectors, safe), metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def gathered_dist_batch(Q: jax.Array, vectors: jax.Array, ids: jax.Array,
                        metric: Metric) -> jax.Array:
    """Rowwise gather+distance: dist(Q[b], vectors[ids[b]]) -> f32[B, K].

    The batched engine's distance primitive; ids<0 padding -> +inf. Uses
    the same elementwise ops as :func:`gathered_dist` so a batched lane
    and a single-query run over the same ids agree bitwise.
    """
    safe = jnp.maximum(ids, 0)
    d = point_dist(Q[:, None, :], gather_rows(vectors, safe), metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def dist_matrix(Q: jax.Array, X: jax.Array, metric: Metric) -> jax.Array:
    """All-pairs distances: Q[b,d], X[n,d] -> [b,n].

    L2 uses the matmul decomposition ||q-x||^2 = ||q||^2 + ||x||^2 - 2 q.x,
    which is how the MXU kernel computes it too.
    """
    dots = Q @ X.T
    if metric == "l2":
        qq = jnp.sum(Q * Q, axis=-1)[:, None]
        xx = jnp.sum(X * X, axis=-1)[None, :]
        return qq + xx - 2.0 * dots
    if metric == "cos":
        return 1.0 - dots
    if metric == "dot":
        return -dots
    raise ValueError(metric)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def brute_force_topk(Q: jax.Array, X: jax.Array, k: int, metric: Metric,
                     mask: jax.Array | None = None):
    """Exact (filtered) kNN oracle. mask: bool[n] selected set; None = all.

    Returns (dists[b,k], ids[b,k]) ascending by distance; unselected rows
    never appear (padded with +inf/-1 when |S| < k).
    """
    d = dist_matrix(Q, X, metric)
    if mask is not None:
        d = jnp.where(mask[None, :], d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    dists = -neg
    ids = jnp.where(jnp.isfinite(dists), idx, -1)
    return dists, ids
