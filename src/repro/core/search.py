"""Filtered HNSW beam search (paper Algorithm 2 + Section 3 heuristics).

JAX adaptation of the paper's search operator:

* the candidates/results priority queues are a single fixed-size *beam* of
  ``efs`` slots sorted by distance, with per-slot ``expanded`` flags -- the
  convergence criterion (stop when the closest unexpanded candidate is
  further than the efs-th best result) is preserved exactly;
* the visited set is a packed bitset (``repro.core.bitset``);
* per-iteration heuristic choice is a ``lax.switch`` over the three fixed
  expansion branches {onehop-s, directed, blind};
* distance-computation accounting matches the paper's definitions:
  ``s_dc``  = distances to *selected* vectors that enter the queues,
  ``t_dc``  = all distances computed (directed additionally pays for
  unvisited unselected 1st-degree neighbors it must order).

Single-query ``jit`` keeps the switch *exclusive* (only the chosen branch
executes) -- this is the faithful latency path used by the benchmarks.
``vmap`` batches are available for throughput serving, at the usual SIMD
cost of evaluating branch union per iteration.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitset
from repro.core.distances import gather_rows, gathered_dist, point_dist
from repro.core.graph import HnswGraph
from repro.core.heuristics import (LENIENCY_FACTOR, UB_ONEHOP_S, Heuristic,
                                   adaptive_rule)


class SearchParams(NamedTuple):
    k: int = 100
    efs: int = 200
    heuristic: int = int(Heuristic.ADAPTIVE_LOCAL)
    metric: str = "l2"
    ub: float = UB_ONEHOP_S
    lf: float = LENIENCY_FACTOR
    two_hop_cap: int = 0          # 0 -> M_L (the paper's M)
    max_iters: int = 0            # 0 -> unbounded (n is the true bound)


class SearchStats(NamedTuple):
    iters: jax.Array
    t_dc: jax.Array               # total distance computations
    s_dc: jax.Array               # selected (inserted) distance computations
    upper_dc: jax.Array           # distance computations in the upper layer
    picks: jax.Array              # int32[3]: times each branch was chosen


class SearchResult(NamedTuple):
    dists: jax.Array              # f32[k]
    ids: jax.Array                # i32[k], -1 padded
    stats: SearchStats


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------


def _take_first(elig: jax.Array, values: jax.Array, width: int,
                budget=None) -> jax.Array:
    """Compact the first (up to ``budget``) eligible values, in order.

    Returns int32[width] padded with -1. ``budget`` may be a traced scalar
    (defaults to ``width``).
    """
    pos = jnp.cumsum(elig.astype(jnp.int32)) - 1
    limit = jnp.minimum(budget, width) if budget is not None else width
    take = elig & (pos < limit)
    tgt = jnp.where(take, pos, width)  # dump slot `width` is sliced off
    out = jnp.full((width + 1,), -1, dtype=jnp.int32)
    out = out.at[tgt].set(jnp.where(take, values, -1), mode="drop")
    return out[:width]


def _dedupe_keep_first(ids: jax.Array) -> jax.Array:
    """Replace repeated ids (keeping the first occurrence) with -1. O(W^2)."""
    w = ids.shape[0]
    i = jnp.arange(w)
    eq_earlier = (ids[None, :] == ids[:, None]) & (i[None, :] < i[:, None])
    dup = eq_earlier.any(axis=1) & (ids >= 0)
    return jnp.where(dup, -1, ids)


# ---------------------------------------------------------------------------
# expansion branches (the Section 3 heuristic space)
# ---------------------------------------------------------------------------
# Every branch maps
#   (nbrs[M], visited[W], sel_bits[W], q, vectors, lower_adj)
# to (cand_ids[KW], cand_d[KW], visited'[W], t_add, s_add)
# with KW = M + K2 fixed so the lax.switch branches have identical types.


def _expand_onehop_s(nbrs, visited, sel_bits, q, vectors, lower, k2, metric):
    m = nbrs.shape[0]
    sel_new = bitset.test(sel_bits, nbrs) & ~bitset.test(visited, nbrs)
    cand1 = jnp.where(sel_new, nbrs, -1)
    d1 = gathered_dist(q, vectors, cand1, metric)
    visited = bitset.set_bits(visited, cand1)
    n1 = (cand1 >= 0).sum()
    pad_ids = jnp.full((k2,), -1, dtype=jnp.int32)
    pad_d = jnp.full((k2,), jnp.inf, dtype=d1.dtype)
    return (jnp.concatenate([cand1, pad_ids]),
            jnp.concatenate([d1, pad_d]),
            visited, n1, n1)


def _second_degree(parents_in_order, visited, sel_bits, q, vectors, lower,
                   k2, budget, metric):
    """Gather 2nd-degree neighborhoods in the given parent order, keep the
    first ``budget`` selected+unvisited unique nodes (paper: "until M many
    selected vectors are explored")."""
    nb2 = lower[jnp.maximum(parents_in_order, 0)]            # [M, M]
    parent_ok = (parents_in_order >= 0)[:, None]
    flat = jnp.where(parent_ok, nb2, -1).reshape(-1)         # [M*M] in order
    elig = (flat >= 0) & bitset.test(sel_bits, flat) & ~bitset.test(visited, flat)
    w2 = 2 * k2
    cand = _take_first(elig, flat, w2)                        # over-take ...
    cand = _dedupe_keep_first(cand)                           # ... dedupe ...
    cand = _take_first(cand >= 0, cand, k2, budget=budget)    # ... then cap
    d2 = gathered_dist(q, vectors, cand, metric)
    visited = bitset.set_bits(visited, cand)
    return cand, d2, visited, (cand >= 0).sum()


def _expand_directed(nbrs, visited, sel_bits, q, vectors, lower, k2, metric):
    """2 hops, parents ordered by distance to v_Q. Pays distance to every
    unvisited 1st-degree neighbor (selected or not) for the ordering."""
    valid = nbrs >= 0
    d_all = gathered_dist(q, vectors, nbrs, metric)           # ordering cost
    new1 = valid & ~bitset.test(visited, nbrs)
    t_order = new1.sum()                                      # t-dc overhead
    sel1 = new1 & bitset.test(sel_bits, nbrs)
    cand1 = jnp.where(sel1, nbrs, -1)
    d1 = jnp.where(sel1, d_all, jnp.inf)
    n1 = sel1.sum()
    # cache/mark everything whose distance we computed
    visited = bitset.set_bits(visited, jnp.where(new1, nbrs, -1))
    order = jnp.argsort(jnp.where(valid, d_all, jnp.inf))
    parents = nbrs[order]
    budget = jnp.maximum(k2 - n1, 0)
    cand2, d2, visited, n2 = _second_degree(
        parents, visited, sel_bits, q, vectors, lower, k2, budget, metric)
    return (jnp.concatenate([cand1, cand2]),
            jnp.concatenate([d1, d2]),
            visited, t_order + n2, n1 + n2)


def _expand_blind(nbrs, visited, sel_bits, q, vectors, lower, k2, metric):
    """2 hops, parents in scan order; no ordering overhead (t-dc == s-dc).

    This is the paper's *improved* ACORN heuristic: all 1st-degree selected
    neighbors are explored before any 2nd-degree neighborhood.
    """
    sel1 = bitset.test(sel_bits, nbrs) & ~bitset.test(visited, nbrs)
    cand1 = jnp.where(sel1, nbrs, -1)
    d1 = gathered_dist(q, vectors, cand1, metric)
    n1 = sel1.sum()
    visited = bitset.set_bits(visited, cand1)
    budget = jnp.maximum(k2 - n1, 0)
    cand2, d2, visited, n2 = _second_degree(
        nbrs, visited, sel_bits, q, vectors, lower, k2, budget, metric)
    return (jnp.concatenate([cand1, cand2]),
            jnp.concatenate([d1, d2]),
            visited, n1 + n2, n1 + n2)


_BRANCHES = (_expand_onehop_s, _expand_directed, _expand_blind)


# ---------------------------------------------------------------------------
# upper layer: greedy descent to find the lower-level entry point
# ---------------------------------------------------------------------------


def greedy_upper(graph: HnswGraph, q: jax.Array, metric: str):
    """Greedy walk on G_U (efs=1, unfiltered). Returns (entry_id, dc)."""

    def cond(c):
        return c[3]

    def body(c):
        pos, d, dc, _ = c
        nbr_pos = graph.upper[pos]                     # [M_U] positions
        valid = nbr_pos >= 0
        nbr_ids = jnp.where(valid, graph.upper_ids[jnp.maximum(nbr_pos, 0)], -1)
        nd = gathered_dist(q, graph.vectors, nbr_ids, metric)
        j = jnp.argmin(nd)
        best = nd[j]
        improved = best < d
        return (jnp.where(improved, nbr_pos[j], pos),
                jnp.where(improved, best, d),
                dc + valid.sum(),
                improved)

    pos0 = graph.entry_pos
    d0 = point_dist(q, gather_rows(graph.vectors, graph.upper_ids[pos0]),
                    metric)
    pos, _, dc, _ = lax.while_loop(cond, body, (pos0, d0, jnp.int32(1), jnp.bool_(True)))
    return graph.upper_ids[pos], dc


# ---------------------------------------------------------------------------
# the beam search
# ---------------------------------------------------------------------------


class _BeamState(NamedTuple):
    d: jax.Array          # f32[efs] ascending is NOT maintained; merged via top_k
    ids: jax.Array        # i32[efs]
    exp: jax.Array        # bool[efs]
    sel: jax.Array        # bool[efs]
    visited: jax.Array    # u32[W]
    it: jax.Array
    t_dc: jax.Array
    s_dc: jax.Array
    picks: jax.Array      # i32[3]


def _frontier_min(st: _BeamState):
    d_un = jnp.where((~st.exp) & (st.ids >= 0), st.d, jnp.inf)
    j = jnp.argmin(d_un)
    return j, d_un[j]


def _r_max(st: _BeamState, efs: int):
    live = st.sel & (st.ids >= 0) & jnp.isfinite(st.d)
    n_sel = live.sum()
    r = jnp.where(live, st.d, -jnp.inf).max()
    return jnp.where(n_sel >= efs, r, jnp.inf)


def beam_search_lower(
    graph: HnswGraph,
    q: jax.Array,
    sel_bits: jax.Array,
    seeds: jax.Array,
    params: SearchParams,
    sigma_g=None,
) -> tuple[jax.Array, jax.Array, SearchStats]:
    """Search G_L. Returns the full beam (dists[efs], ids[efs]) sorted
    ascending with unselected/invalid slots pushed to +inf, plus stats.

    ``seeds``: int32[n_seeds] entry node ids (from greedy_upper, or node 0).
    ``sigma_g``: global selectivity |S|/|V| (traced ok); required for
    ADAPTIVE_GLOBAL, used as metadata otherwise.
    """
    efs = params.efs
    metric = params.metric
    mode = int(params.heuristic)
    m_l = graph.m_l
    k2 = params.two_hop_cap or m_l
    max_iters = params.max_iters or graph.n

    vectors, lower = graph.vectors, graph.lower

    if mode == int(Heuristic.ONEHOP_A):
        # unfiltered original HNSW == onehop-s with the full mask
        sel_bits = bitset.full_mask(graph.n)
        mode = int(Heuristic.ONEHOP_S)

    if mode == int(Heuristic.ADAPTIVE_GLOBAL):
        if sigma_g is None:
            sigma_g = bitset.count(sel_bits) / graph.n
        global_branch = adaptive_rule(sigma_g, m_l, params.ub, params.lf)
    else:
        global_branch = jnp.int32(mode if mode <= 2 else 0)

    # --- init beam with seeds -------------------------------------------
    n_seeds = seeds.shape[0]
    seed_d = gathered_dist(q, vectors, seeds, metric)
    seed_sel = bitset.test(sel_bits, seeds)
    pad = efs - n_seeds
    st = _BeamState(
        d=jnp.concatenate([seed_d, jnp.full((pad,), jnp.inf, seed_d.dtype)]),
        ids=jnp.concatenate([seeds, jnp.full((pad,), -1, jnp.int32)]),
        exp=jnp.zeros((efs,), bool),
        sel=jnp.concatenate([seed_sel, jnp.zeros((pad,), bool)]),
        visited=bitset.set_bits(
            jnp.zeros((bitset.n_words(graph.n),), jnp.uint32), seeds),
        it=jnp.int32(0),
        # seed/entry distances are accounted under upper_dc by the caller;
        # t_dc/s_dc measure the heuristic's exploration only, so the
        # paper's "blind: t-dc == s-dc" identity holds exactly
        t_dc=jnp.int32(0),
        s_dc=jnp.int32(0),
        picks=jnp.zeros((3,), jnp.int32),
    )

    def cond(st: _BeamState):
        _, d_min = _frontier_min(st)
        keep_going = (d_min < jnp.inf) & (d_min <= _r_max(st, efs))
        return keep_going & (st.it < max_iters)

    def body(st: _BeamState) -> _BeamState:
        j, _ = _frontier_min(st)
        c_min = st.ids[j]
        nbrs = lower[c_min]                                   # int32[M_L]

        if mode == int(Heuristic.ADAPTIVE_LOCAL):
            deg = (nbrs >= 0).sum()
            n_sel_nbrs = bitset.count_members(sel_bits, nbrs)
            sigma_l = n_sel_nbrs / jnp.maximum(deg, 1)
            branch = adaptive_rule(sigma_l, m_l, params.ub, params.lf)
        else:
            branch = global_branch

        cand_ids, cand_d, visited, t_add, s_add = lax.switch(
            branch,
            [functools.partial(f, k2=k2, metric=metric) for f in _BRANCHES],
            nbrs, st.visited, sel_bits, q, vectors, lower,
        )

        # retire the expanded slot; unselected slots are dropped entirely
        # (they are neither frontier nor results once expanded)
        exp = st.exp.at[j].set(True)
        d = st.d.at[j].set(jnp.where(st.sel[j], st.d[j], jnp.inf))

        all_d = jnp.concatenate([d, jnp.where(cand_ids >= 0, cand_d, jnp.inf)])
        all_id = jnp.concatenate([st.ids, cand_ids])
        all_exp = jnp.concatenate([exp, jnp.zeros_like(cand_ids, dtype=bool)])
        all_sel = jnp.concatenate([st.sel, cand_ids >= 0])

        neg, order = lax.top_k(-all_d, efs)
        return _BeamState(
            d=-neg,
            ids=all_id[order],
            exp=all_exp[order],
            sel=all_sel[order],
            visited=visited,
            it=st.it + 1,
            t_dc=st.t_dc + t_add.astype(jnp.int32),
            s_dc=st.s_dc + s_add.astype(jnp.int32),
            picks=st.picks.at[branch].add(1),
        )

    st = lax.while_loop(cond, body, st)

    # results: selected slots only, ascending
    res_d = jnp.where(st.sel & (st.ids >= 0), st.d, jnp.inf)
    neg, order = lax.top_k(-res_d, efs)
    out_d = -neg
    out_id = jnp.where(jnp.isfinite(out_d), st.ids[order], -1)
    stats = SearchStats(iters=st.it, t_dc=st.t_dc, s_dc=st.s_dc,
                        upper_dc=jnp.int32(0), picks=st.picks)
    return out_d, out_id, stats


@functools.partial(jax.jit, static_argnames=("params",))
def search(graph: HnswGraph, q: jax.Array, sel_bits: jax.Array,
           params: SearchParams, sigma_g=None) -> SearchResult:
    """Full 2-level filtered search for one query (paper's QUERY_HNSW_INDEX).

    Upper layer is searched unfiltered with k=1 (greedy) to find the entry
    point; the lower layer runs the configured heuristic.
    """
    entry, upper_dc = greedy_upper(graph, q, params.metric)
    beam_d, beam_id, stats = beam_search_lower(
        graph, q, sel_bits, entry[None], params, sigma_g=sigma_g)
    k = params.k
    res = SearchResult(
        dists=beam_d[:k],
        ids=beam_id[:k],
        # +1: the entry vector's own distance at the lower level
        stats=stats._replace(upper_dc=upper_dc.astype(jnp.int32) + 1),
    )
    return res


@functools.partial(jax.jit, static_argnames=("params",))
def search_batch(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                 params: SearchParams, sigma_g=None) -> SearchResult:
    """vmap batch path, kept as the reference oracle for the dedicated
    batched-frontier engine (``repro.core.search_batch.search_many``).

    It pays the branch-union cost per iteration (see module doc) --
    production batch traffic should use the batched engine instead.
    ``sel_bits`` may be one shared ``[W]`` semimask or a per-lane
    ``[B, W]`` stack (with ``sigma_g`` scalar or per-lane ``[B]``).
    """
    per_lane_sigma = sigma_g is not None and jnp.ndim(sigma_g) == 1
    if sel_bits.ndim == 2:
        if per_lane_sigma:
            return jax.vmap(
                lambda q, s, g: search(graph, q, s, params, g)
            )(Q, sel_bits, jnp.asarray(sigma_g))
        return jax.vmap(
            lambda q, s: search(graph, q, s, params, sigma_g))(Q, sel_bits)
    if per_lane_sigma:
        return jax.vmap(
            lambda q, g: search(graph, q, sel_bits, params, g)
        )(Q, jnp.asarray(sigma_g))
    return jax.vmap(lambda q: search(graph, q, sel_bits, params, sigma_g))(Q)
