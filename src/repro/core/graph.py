"""HNSW graph containers.

NaviX is a 2-level HNSW (paper Section 4.1): the lower level ``G_L`` holds
all ``n`` vectors with max degree ``M_L``; the upper level ``G_U`` holds a
``sample_rate`` (default 5%) sample with max degree ``M_U`` and is used only
to find a good entry point. The paper sets ``M_L = 2 * M_U``.

Adjacency is stored as fixed-degree padded arrays (``-1`` padding) -- the
JAX analogue of Kuzu's disk CSR (the storage layer also exposes a true CSR
view for the host-side substrates).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class HnswGraph(NamedTuple):
    """Index topology + vector payload (device-resident)."""

    # lower level: all n vectors
    lower: jax.Array        # int32[n, M_L], -1 padded
    lower_deg: jax.Array    # int32[n]
    # upper level: sampled subset, indices are *positions* in upper_ids
    upper: jax.Array        # int32[n_u, M_U] positions into upper_ids, -1 padded
    upper_deg: jax.Array    # int32[n_u]
    upper_ids: jax.Array    # int32[n_u] -> node id in [0, n)
    entry_pos: jax.Array    # int32 scalar: entry position into upper_ids
    vectors: jax.Array      # f32[n, d] (normalized when metric == "cos"),
                            # or a QuantizedStore (int8 codes + per-vector
                            # scale) when the index is quantized-resident

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def m_l(self) -> int:
        return self.lower.shape[1]

    @property
    def m_u(self) -> int:
        return self.upper.shape[1]

    @property
    def n_upper(self) -> int:
        return self.upper_ids.shape[0]

    def nbytes(self) -> int:
        # tree_leaves, not `for a in self`: the vectors field may itself
        # be a pytree (QuantizedStore) rather than one array
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.tree_util.tree_leaves(self))

    def vector_nbytes(self) -> int:
        """Device-resident bytes of the vector payload alone (the bench's
        capacity accounting: int8 codes + scales vs the f32 store)."""
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.tree_util.tree_leaves(self.vectors))


def empty_graph(n: int, d: int, m_l: int, m_u: int, n_upper: int,
                vectors: jax.Array) -> HnswGraph:
    return HnswGraph(
        lower=jnp.full((n, m_l), -1, dtype=jnp.int32),
        lower_deg=jnp.zeros((n,), dtype=jnp.int32),
        upper=jnp.full((n_upper, m_u), -1, dtype=jnp.int32),
        upper_deg=jnp.zeros((n_upper,), dtype=jnp.int32),
        upper_ids=jnp.full((n_upper,), -1, dtype=jnp.int32),
        entry_pos=jnp.asarray(0, dtype=jnp.int32),
        vectors=vectors,
    )


def degree_histogram(graph: HnswGraph) -> np.ndarray:
    deg = np.asarray(graph.lower_deg)
    return np.bincount(deg, minlength=graph.m_l + 1)


def check_symmetric_fraction(graph: HnswGraph, sample: int = 1024,
                             seed: int = 0) -> float:
    """Fraction of sampled directed edges whose reverse edge also exists.

    HNSW keeps edges mostly (not strictly) symmetric because backward edges
    get RNG-pruned; a healthy build typically shows > 0.5.
    """
    rng = np.random.default_rng(seed)
    lower = np.asarray(graph.lower)
    deg = np.asarray(graph.lower_deg)
    nodes = rng.integers(0, graph.n, size=sample)
    hits = total = 0
    for u in nodes:
        for v in lower[u, : deg[u]]:
            if v < 0:
                continue
            total += 1
            if u in lower[v, : deg[v]]:
                hits += 1
    return hits / max(total, 1)
