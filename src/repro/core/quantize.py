"""Int8 vector quantization (the DiskANN-regime analogue, paper Section 5.8).

DiskANN keeps compressed vectors in memory and re-ranks with exact
distances; NaviX-cold-quant mimics it. Here: symmetric per-vector int8
quantization; the search runs on quantized distances (same quantization
error as a real int8 pipeline -- the arithmetic is exact, the *values* are
quantized) and the final beam is re-ranked with full-precision distances.
On TPU the quantized distance runs in the int8 Pallas kernel
(repro.kernels.quantized).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedStore(NamedTuple):
    """The int8-resident vector representation.

    A ``QuantizedStore`` can sit directly in ``HnswGraph.vectors``: it
    exposes the ``shape`` of the logical f32 store so ``graph.n`` /
    ``graph.dim`` keep working, and the engines gather + dequantize rows
    on the fly (``repro.core.distances.gather_rows``), so no ``[n, d]``
    f32 buffer is ever materialized.
    """

    codes: jax.Array    # int8[n, d]
    scale: jax.Array    # f32[n]   per-vector symmetric scale

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def shape(self) -> tuple:
        """Logical [n, d] shape of the store (mirrors the f32 array)."""
        return self.codes.shape

    def nbytes(self) -> int:
        return self.codes.size + 4 * self.scale.size


def quantize(vectors: jax.Array) -> QuantizedStore:
    amax = jnp.max(jnp.abs(vectors), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(vectors / scale[:, None]), -127, 127)
    return QuantizedStore(codes=codes.astype(jnp.int8), scale=scale.astype(jnp.float32))


def dequantize(store: QuantizedStore) -> jax.Array:
    return store.codes.astype(jnp.float32) * store.scale[:, None]


def rerank(q: jax.Array, vectors: jax.Array, ids: jax.Array, k: int,
           metric: str):
    """Exact re-rank of a candidate id list; returns (dists[k], ids[k]).

    ``ids`` may carry ``-1`` padding (never surfaces -- padded slots rank
    at +inf and come back as ``-1``) and duplicates (counted once: repeats
    after the first occurrence are dropped before ranking, so a k-slot
    result never spends two slots on one node).
    """
    from repro.core.distances import gathered_dist
    from repro.core.search import _dedupe_keep_first
    ids = _dedupe_keep_first(ids)
    d = gathered_dist(q, vectors, ids, metric)
    neg, order = jax.lax.top_k(-d, k)
    out_d = -neg
    return out_d, jnp.where(jnp.isfinite(out_d), ids[order], -1)


def rerank_many(Q: jax.Array, vectors: jax.Array, ids: jax.Array, k: int,
                metric: str):
    """Lane-vectorized exact re-rank: Q[b, d], ids[b, w] ->
    (dists[b, k], ids[b, k]). Lane b is bitwise ``rerank`` on row b --
    the batched tail of ``search_quantized_many``."""
    return jax.vmap(lambda q, i: rerank(q, vectors, i, k, metric))(Q, ids)
