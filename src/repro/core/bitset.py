"""Packed-bitset semimask primitives.

The paper passes the selected set S from the selection subquery to the kNN
search operator as a *node semimask* (Kuzu's sideways information passing).
Here a semimask over ``n`` nodes is a packed ``uint32[ceil(n/32)]`` bitset.
Local selectivity checks are pure bit tests -- zero distance computations,
exactly matching Section 3.2 of the paper.

All functions are jit-/vmap-compatible; ids < 0 are treated as padding and
test as False / are never set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n: int) -> int:
    return -(-n // WORD_BITS)


def pack(mask: jax.Array) -> jax.Array:
    """bool[n] -> uint32[ceil(n/32)] (little-endian bit order within words)."""
    n = mask.shape[-1]
    pad = n_words(n) * WORD_BITS - n
    m = jnp.pad(mask.astype(jnp.uint32), [(0, 0)] * (mask.ndim - 1) + [(0, pad)])
    m = m.reshape(mask.shape[:-1] + (n_words(n), WORD_BITS))
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (m * weights).sum(axis=-1).astype(jnp.uint32)


def pack_np(mask: np.ndarray) -> np.ndarray:
    """Host-side :func:`pack`: bool[..., n] -> uint32[..., ceil(n/32)].

    Bit-identical to ``np.asarray(pack(mask))`` but pure numpy -- the
    serving tier packs one semimask per *distinct plan* on the host
    between device chunks, and an eager jnp pack there costs a dispatch
    chain per plan (it dominated the drain wall). ``np.packbits`` with
    little-endian bit order viewed as little-endian uint32 reproduces
    ``pack``'s ``bit i == element i`` layout exactly (asserted in
    tests/test_overlap.py and property-tested in tests/test_bitset.py).
    """
    m = np.asarray(mask, dtype=bool)
    n = m.shape[-1]
    pad = n_words(n) * WORD_BITS - n
    if pad:
        m = np.concatenate(
            [m, np.zeros(m.shape[:-1] + (pad,), bool)], axis=-1)
    packed = np.packbits(m, axis=-1, bitorder="little")
    return np.ascontiguousarray(packed).view(np.uint32)


def unpack(bits: jax.Array, n: int) -> jax.Array:
    """uint32[W] -> bool[n]."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    expanded = (bits[..., :, None] >> shifts[None, :]) & jnp.uint32(1)
    flat = expanded.reshape(bits.shape[:-1] + (-1,))
    return flat[..., :n].astype(bool)


def test(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Test membership bits for an int32 id vector. ids<0 -> False."""
    safe = jnp.maximum(ids, 0)
    word = safe >> 5
    bit = (safe & 31).astype(jnp.uint32)
    hit = (bits[word] >> bit) & jnp.uint32(1)
    return jnp.where(ids >= 0, hit.astype(bool), False)


def set_bits(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Set bits for ids; ids<0 ignored. Duplicate-safe.

    The per-word OR is realized as a scatter-add of *distinct* powers of
    two: ids are sorted so duplicates become adjacent and only the first
    occurrence of each run (that is not already set) contributes. A plain
    additive scatter would carry duplicate contributions into neighboring
    bits, silently corrupting the set.
    """
    s = jnp.sort(ids)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]]) if s.shape[0] > 1 else (
        jnp.ones(s.shape, bool))
    fresh = first & (s >= 0) & ~test(bits, s)
    safe = jnp.maximum(s, 0)
    word = jnp.where(fresh, safe >> 5, 0)
    val = jnp.where(fresh, (jnp.uint32(1) << (safe & 31).astype(jnp.uint32)), jnp.uint32(0))
    return bits.at[word].add(val)


def popcount(x: jax.Array) -> jax.Array:
    """Per-word popcount (uint32)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def count(bits: jax.Array) -> jax.Array:
    """Total number of set bits."""
    return popcount(bits).astype(jnp.int32).sum()


def count_members(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """How many of the (padded) ids are set -- the sigma_l numerator."""
    return test(bits, ids).astype(jnp.int32).sum()


# -- batched (per-lane) primitives ------------------------------------------
# A batch of semimasks is packed as uint32[B, W]: one independent bitset per
# lane. These are the [B, W] counterparts the batched-frontier engine uses
# when every lane carries its own selection subquery's S (mixed-plan device
# batches); ids < 0 stay padding lane-wise.

#: bool[B, n] -> uint32[B, W] (``pack`` already maps over leading dims).
pack_batch = pack

#: ([B, W], [B, K]) -> bool[B, K]: lane b tests its own bitset.
test_batch = jax.vmap(test)


def set_bits_batch(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Lane-wise set_bits: ([B, W], [B, K]) -> uint32[B, W].

    Bitwise-identical to ``vmap(set_bits)`` but realized as ONE flat
    1-D scatter-add over ``[B * W]`` (lane-offset indices) instead of a
    batched scatter -- XLA CPU lowers per-lane scatters to serial loops,
    which dominated the batched engine's iteration cost.
    """
    bsz, w = bits.shape
    s = jnp.sort(ids, axis=1)
    first = (jnp.concatenate([jnp.ones((bsz, 1), bool),
                              s[:, 1:] != s[:, :-1]], axis=1)
             if s.shape[1] > 1 else jnp.ones(s.shape, bool))
    fresh = first & (s >= 0) & ~test_batch(bits, s)
    safe = jnp.maximum(s, 0)
    word = jnp.where(fresh, safe >> 5, 0)
    val = jnp.where(fresh,
                    jnp.uint32(1) << (safe & 31).astype(jnp.uint32),
                    jnp.uint32(0))
    flat_idx = (jnp.arange(bsz, dtype=word.dtype)[:, None] * w
                + word).reshape(-1)
    flat = bits.reshape(-1).at[flat_idx].add(val.reshape(-1))
    return flat.reshape(bsz, w)

def count_members_batch(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Leading-dim-matched membership count: ([..., W], [..., K]) -> i32[...].

    The per-lane sigma_l numerators. Any number of leading dims is
    supported as long as they match (``[B, W]`` lanes, ``[S, B, W]``
    shard-stacked lanes, ...); integer-exact against ``vmap(count_members)``
    on the 2-D form. ids < 0 are padding and never count.
    """
    safe = jnp.maximum(ids, 0)
    word = safe >> 5
    bit = (safe & 31).astype(jnp.uint32)
    hit = (jnp.take_along_axis(bits, word, axis=-1) >> bit) & jnp.uint32(1)
    return jnp.where(ids >= 0, hit.astype(jnp.int32), 0).sum(axis=-1)


def count_batch(bits: jax.Array) -> jax.Array:
    """Per-lane popcount total: uint32[..., W] -> i32[...]."""
    return popcount(bits).astype(jnp.int32).sum(axis=-1)


def broadcast_lanes(bits: jax.Array, bsz: int) -> jax.Array:
    """Normalize a semimask to per-lane form: [W] -> [B, W] (a broadcast
    view; XLA never materializes the copy), [B, W] passes through after a
    lane-count check."""
    if bits.ndim == 1:
        return jnp.broadcast_to(bits, (bsz,) + bits.shape)
    if bits.shape[0] != bsz:
        raise ValueError(f"per-lane semimask has {bits.shape[0]} lanes "
                         f"but the batch has {bsz}")
    return bits


def broadcast_shard_lanes(bits: jax.Array, bsz: int) -> jax.Array:
    """Normalize a shard-stacked semimask to per-lane form: [S, W] ->
    [S, B, W] (a broadcast view, like :func:`broadcast_lanes`),
    [S, B, W] passes through after a lane-count check."""
    if bits.ndim == 2:
        s, w = bits.shape
        return jnp.broadcast_to(bits[:, None, :], (s, bsz, w))
    if bits.shape[1] != bsz:
        raise ValueError(f"per-lane sharded semimask has {bits.shape[1]} "
                         f"lanes but the batch has {bsz}")
    return bits


def full_mask(n: int, value: bool = True) -> jax.Array:
    if value:
        w = n_words(n)
        bits = np.full(w, 0xFFFFFFFF, dtype=np.uint32)
        # clear tail padding bits so count() == n
        tail = n - (w - 1) * WORD_BITS
        if tail < WORD_BITS:
            bits[-1] = (1 << tail) - 1
        return jnp.asarray(bits)
    return jnp.zeros(n_words(n), dtype=jnp.uint32)
