"""NavixIndex -- the single-index handle (compatibility layer).

The primary public API is ``repro.api.NavixDB``: a facade owning the graph
store, an index catalog, and declarative plan execution (the paper's
CREATE_HNSW_INDEX / QUERY_HNSW_INDEX as plan operators). ``NavixIndex``
remains the thin per-index layer underneath it:

    idx, build_stats = NavixIndex.create(vectors, NavixConfig(metric="cos"))
    res = idx.search(q, k=100, semimask=mask)   # adaptive-local by default

Search defaults to the paper's final design (adaptive-local); every
heuristic from Table 1 is selectable. Per-query latency benchmarking uses
``search`` (exclusive lax.switch branches); ``search_many`` is the batch
path used by the serving engine. Indexes registered in a ``NavixDB``
catalog share its compiled-program cache (``program_cache``), so repeated
plan shapes never retrace even through this compatibility API.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.build import BuildParams, BuildStats, build
from repro.core.distances import brute_force_topk, normalize, validate_metric
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic
from repro.core.postfilter import postfilter_search
from repro.core.quantize import QuantizedStore, dequantize, quantize
from repro.core.search import SearchParams, SearchResult, search
from repro.core.search_batch import resolve_engine
from repro.storage.columnar import ExactTier


class NavixConfig(NamedTuple):
    m_u: int = 16                 # paper default M=32 upper / 64 lower at scale
    ef_construction: int = 100
    sample_rate: float = 0.05     # upper-layer sample (paper: 5%)
    metric: str = "l2"
    batch_size: int = 256
    seed: int = 0

    def build_params(self) -> BuildParams:
        return BuildParams(m_u=self.m_u, ef_construction=self.ef_construction,
                           sample_rate=self.sample_rate, metric=self.metric,
                           batch_size=self.batch_size, seed=self.seed)


@dataclasses.dataclass
class NavixIndex:
    graph: HnswGraph
    config: NavixConfig
    quantized: Optional[QuantizedStore] = None
    # exact f32 tier (host / memmap) paired with a quantized-resident graph;
    # finalizes quantized searches by re-ranking the final beam exactly
    exact: Optional[ExactTier] = None
    # set when the index is registered in a NavixDB catalog; routes search
    # through the shared AOT compiled-program cache (repro.api.plan_compile)
    program_cache: Optional[object] = None
    # lazily-built quantized sibling for plain-f32 indexes (search_quantized
    # compatibility path); never part of the persisted state
    _qview: Optional["NavixIndex"] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- creation ---------------------------------------------------------
    @classmethod
    def create(cls, vectors, config: NavixConfig = NavixConfig()
               ) -> tuple["NavixIndex", BuildStats]:
        validate_metric(config.metric)
        graph, stats = build(jnp.asarray(vectors), config.build_params())
        return cls(graph=graph, config=config), stats

    @classmethod
    def from_graph(cls, graph: HnswGraph, config: NavixConfig) -> "NavixIndex":
        return cls(graph=graph, config=config)

    # -- residency ----------------------------------------------------------
    @property
    def is_quantized(self) -> bool:
        """True when the device-resident vectors are int8 codes + scales."""
        return isinstance(self.graph.vectors, QuantizedStore)

    def quantize_resident(self, mmap_path=None) -> "NavixIndex":
        """Return a sibling index whose device residency is int8.

        The graph's vector payload becomes the ``QuantizedStore`` (codes +
        per-vector scales; the engines' gather+distance dispatch dequantizes
        per gathered row, so no [n, d] f32 buffer ever exists on device) and
        the full-precision rows move to a host-side :class:`ExactTier`
        (``mmap_path`` spills them to disk). Shares this index's
        compiled-program cache; programs key on residency, so f32 and int8
        programs coexist without retraces.
        """
        if self.is_quantized:
            return self
        store = self.quantized
        if store is None:
            store = quantize(self.graph.vectors)
        exact = ExactTier.build(np.asarray(self.graph.vectors),
                                self.config.metric, mmap_path=mmap_path)
        return dataclasses.replace(
            self, graph=self.graph._replace(vectors=store), quantized=store,
            exact=exact, _qview=None)

    def _quantized_view(self) -> "NavixIndex":
        """The index search_quantized* runs on: self if already
        int8-resident, else a cached quantized sibling (built once)."""
        if self.is_quantized:
            return self
        if self._qview is None:
            self._qview = self.quantize_resident()
            self.quantized = self._qview.quantized
        # the sibling always follows this index's current catalog cache
        self._qview.program_cache = self.program_cache
        return self._qview

    # -- semimasks ----------------------------------------------------------
    def pack_semimask(self, mask) -> jax.Array:
        """Pack a semimask (or a per-lane stack of semimasks).

        Accepts bool[n] / bool[B, n] (or a list of bool[n] masks), and
        pre-packed uint32[W] / uint32[B, W]. 2-D results are the
        per-lane form the batched engine fuses mixed-plan batches with.
        """
        if isinstance(mask, (list, tuple)):
            mask = np.stack([np.asarray(m) for m in mask])
        if not isinstance(mask, jax.Array):
            # host data packs on the host (one numpy pass) -- an eager
            # jnp pack costs a dispatch chain per mask
            mask = np.asarray(mask)
            if mask.dtype != np.uint32:
                if mask.shape[-1] != self.graph.n:
                    raise ValueError(
                        f"semimask covers {mask.shape[-1]} nodes but this "
                        f"index has {self.graph.n}")
                return jnp.asarray(bitset.pack_np(mask))
            mask = jnp.asarray(mask)
        if mask.dtype == jnp.uint32:
            want = bitset.n_words(self.graph.n)
            if mask.shape[-1] != want:
                raise ValueError(
                    f"pre-packed semimask has {mask.shape[-1]} uint32 words "
                    f"but this index ({self.graph.n} nodes) needs {want}; "
                    f"was it packed for a differently-sized index?")
            return mask
        if mask.shape[-1] != self.graph.n:
            raise ValueError(
                f"semimask covers {mask.shape[-1]} nodes but this index "
                f"has {self.graph.n}")
        return bitset.pack(mask.astype(bool))

    def full_semimask(self) -> jax.Array:
        return bitset.full_mask(self.graph.n)

    def sigma(self, sel_bits: jax.Array):
        """Selectivity |S|/|V|: float for a [W] mask, f32[B] per lane for
        a per-lane [B, W] stack."""
        if sel_bits.ndim == 2:
            return bitset.count_batch(sel_bits).astype(jnp.float32) / \
                self.graph.n
        return float(bitset.count(sel_bits)) / self.graph.n

    # -- search -------------------------------------------------------------
    def _params(self, k, efs, heuristic, max_iters=0) -> SearchParams:
        h = (Heuristic.from_name(heuristic) if isinstance(heuristic, str)
             else Heuristic(heuristic))
        return SearchParams(k=k, efs=max(efs, k), heuristic=int(h),
                            metric=self.config.metric, max_iters=max_iters)

    def _prep_query(self, q) -> jax.Array:
        q = jnp.asarray(q, dtype=jnp.float32)
        if self.config.metric == "cos":
            q = normalize(q)
        return q

    def search(self, q, k: int = 100, efs: int = 0, semimask=None,
               heuristic="adaptive_local", sigma_g=None) -> SearchResult:
        """Filtered kNN for a single query vector (paper QUERY_HNSW_INDEX)."""
        efs = efs or 2 * k
        sel = (self.full_semimask() if semimask is None
               else self.pack_semimask(semimask))
        if sigma_g is None:
            sigma_g = self.sigma(sel)
        params = self._params(k, efs, heuristic)
        if self.program_cache is not None:
            return self.program_cache.search(self.graph,
                                             self._prep_query(q), sel,
                                             params, sigma_g)
        return search(self.graph, self._prep_query(q), sel, params,
                      sigma_g=sigma_g)

    def search_many(self, Q, k: int = 100, efs: int = 0, semimask=None,
                    heuristic="adaptive_local",
                    engine: str = "batched") -> SearchResult:
        """Batched search -- the serving-throughput path.

        ``engine="batched"`` (default) runs the batched-frontier engine
        (``repro.core.search_batch``): one while-loop over the whole
        batch, per-query convergence masking, one shared expansion per
        iteration. ``engine="vmap"`` keeps the vmapped single-query
        program as a reference oracle (pays the branch union per
        iteration; see the module docs). Both return lane-for-lane
        identical results.

        ``semimask`` may be one shared mask (bool[n] / uint32[W]) or a
        per-lane stack (bool[B, n], a list of B masks, or uint32[B, W]),
        in which case lane b searches its own selected set -- the
        mixed-plan device-batching path.
        """
        fn = resolve_engine(engine)
        efs = efs or 2 * k
        sel = (self.full_semimask() if semimask is None
               else self.pack_semimask(semimask))
        sigma_g = self.sigma(sel)
        params = self._params(k, efs, heuristic)
        if self.program_cache is not None:
            return self.program_cache.batch(engine)(
                self.graph, self._prep_query(Q), sel, params, sigma_g)
        return fn(self.graph, self._prep_query(Q), sel, params,
                  sigma_g=sigma_g)

    def search_quantized(self, q, k: int = 100, efs: int = 0, semimask=None,
                         heuristic="adaptive_local"):
        """DiskANN-regime search: int8-resident beam + exact re-rank (S 5.8).

        The beam loop runs directly on the int8 codes (fused dequantizing
        gather+distance; NO [n, d] f32 store is materialized, per call or
        ever) and the final beam -- the full ``efs`` frontier -- is
        re-ranked host-side against the :class:`ExactTier` f32 rows, then
        cut to ``k``.
        """
        qidx = self._quantized_view()
        efs = max(efs or 2 * k, k)
        sel = (qidx.full_semimask() if semimask is None
               else qidx.pack_semimask(semimask))
        qv = self._prep_query(q)
        # full-beam params (k == efs): the exact tier does the final cut
        params = self._params(efs, efs, heuristic)
        if qidx.program_cache is not None:
            res = qidx.program_cache.search(qidx.graph, qv, sel, params,
                                            qidx.sigma(sel))
        else:
            res = search(qidx.graph, qv, sel, params, sigma_g=qidx.sigma(sel))
        d, ids = qidx.exact.rerank(np.asarray(qv), np.asarray(res.ids), k)
        return SearchResult(dists=jnp.asarray(d), ids=jnp.asarray(ids),
                            stats=res.stats)

    def search_quantized_many(self, Q, k: int = 100, efs: int = 0,
                              semimask=None, heuristic="adaptive_local",
                              engine: str = "batched"):
        """Batched DiskANN-regime search: the int8-resident store composed
        with the batched-frontier engine, plus a lane-vectorized exact
        re-rank against the f32 tier.

        Lane-for-lane equivalent to :meth:`search_quantized` per query
        (``semimask`` accepts the same shared / per-lane forms as
        :meth:`search_many`).
        """
        qidx = self._quantized_view()
        fn = resolve_engine(engine)
        efs = max(efs or 2 * k, k)
        sel = (qidx.full_semimask() if semimask is None
               else qidx.pack_semimask(semimask))
        Qp = self._prep_query(Q)
        params = self._params(efs, efs, heuristic)
        if qidx.program_cache is not None:
            res = qidx.program_cache.batch(engine)(qidx.graph, Qp, sel,
                                                   params, qidx.sigma(sel))
        else:
            res = fn(qidx.graph, Qp, sel, params, sigma_g=qidx.sigma(sel))
        d, ids = qidx.exact.rerank_many(np.asarray(Qp), np.asarray(res.ids),
                                        k)
        return SearchResult(dists=jnp.asarray(d), ids=jnp.asarray(ids),
                            stats=res.stats)

    def search_postfilter(self, q, k: int = 100, semimask=None):
        sel = (self.full_semimask() if semimask is None
               else self.pack_semimask(semimask))
        return postfilter_search(self.graph, self._prep_query(q), sel, k,
                                 metric=self.config.metric)

    # -- oracles ------------------------------------------------------------
    def brute_force(self, Q, k: int = 100, semimask=None):
        Q = jnp.atleast_2d(self._prep_query(Q))
        mask = None
        if semimask is not None:
            sel = self.pack_semimask(semimask)
            mask = bitset.unpack(sel, self.graph.n)
        vectors = self.graph.vectors
        if self.is_quantized:
            # the oracle scores exact f32 rows, not codes: prefer the exact
            # tier; a bare quantized graph falls back to dequantizing (this
            # is a test oracle, not a search path)
            vectors = (jnp.asarray(np.asarray(self.exact.vectors))
                       if self.exact is not None
                       else dequantize(self.graph.vectors))
        return brute_force_topk(Q, vectors, k, self.config.metric,
                                mask=mask)

    def recall(self, res_ids, true_ids) -> float:
        """recall@k with -1-padding awareness (both arrays [k] or [b,k])."""
        res = np.atleast_2d(np.asarray(res_ids))
        true = np.atleast_2d(np.asarray(true_ids))
        hits = 0
        denom = 0
        for r, t in zip(res, true):
            tset = set(int(x) for x in t if x >= 0)
            denom += len(tset)
            # set intersection: a duplicated result id is one hit, not many
            hits += len(tset & set(int(x) for x in r if x >= 0))
        return hits / max(denom, 1)
